"""Hypothesis property tests on system invariants.

Skips cleanly when hypothesis is not installed (it is a dev-only dependency,
declared in requirements-dev.txt / pyproject's ``test`` extra); the non-random
invariant coverage lives in the plain pytest modules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import binning, dynamic
from repro.core.histogram import compute_histogram
from repro.core.types import FedGBFConfig
from repro.federation import protocol

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    n=st.integers(10, 300),
    d=st.integers(1, 8),
    num_bins=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_binning_bounds_and_monotonicity(n, d, num_bins, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)) * rng.lognormal(size=(1, d)), jnp.float32)
    b, edges = binning.fit_bin(x, num_bins)
    # bounds
    assert int(b.min()) >= 0 and int(b.max()) < num_bins
    # monotone: larger value -> bin id not smaller (per feature)
    xa = np.asarray(x)
    ba = np.asarray(b)
    for f in range(d):
        order = np.argsort(xa[:, f], kind="stable")
        assert np.all(np.diff(ba[order, f]) >= 0)
    # edges non-decreasing
    assert np.all(np.diff(np.asarray(edges), axis=1) >= 0)


@settings(**SETTINGS)
@given(
    n=st.integers(16, 400),
    d=st.integers(1, 6),
    nodes=st.sampled_from([1, 2, 4]),
    parts=st.integers(1, 5),
    seed=st.integers(0, 2**16),
)
def test_histogram_additivity_under_partition(n, d, nodes, parts, seed):
    """sum of per-part histograms == whole histogram, for ANY sample partition
    (the invariant that makes both the data-axis psum and VFL exact)."""
    rng = np.random.default_rng(seed)
    B = 8
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    assign = jnp.asarray(rng.integers(0, nodes, n), jnp.int32)

    whole = compute_histogram(binned, g, h, w, assign, nodes, B)
    labels = rng.integers(0, parts, n)
    acc = jnp.zeros_like(whole)
    for p in range(parts):
        m = jnp.asarray((labels == p).astype(np.float32))
        acc = acc + compute_histogram(binned, g, h, w * m, assign, nodes, B)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(whole), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(16, 400),
    d=st.integers(1, 6),
    parents=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_sibling_subtraction_additive(n, d, parents, seed):
    """parent == left + right for ANY assignment/weights, and the derived
    frontier matches the direct one (DESIGN.md §6) — the algebra behind
    ``TreeConfig.hist_subtraction``."""
    from repro.core.histogram import as_child_fn, derive_sibling

    rng = np.random.default_rng(seed)
    B = 8
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.asarray(rng.random(n).astype(np.float32))  # weighted (GOSS) masks
    assign = jnp.asarray(rng.integers(0, 2 * parents, n), jnp.int32)

    parent = compute_histogram(binned, g, h, w, assign // 2, parents, B)
    left = as_child_fn(compute_histogram)(binned, g, h, w, assign, parents, B)
    right_w = w * (assign % 2).astype(w.dtype)
    right = compute_histogram(binned, g, h, right_w, assign // 2, parents, B)
    np.testing.assert_allclose(
        np.asarray(left + right), np.asarray(parent), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(derive_sibling(parent, left)),
        np.asarray(compute_histogram(binned, g, h, w, assign, 2 * parents, B)),
        rtol=1e-3, atol=1e-4,
    )


@settings(**SETTINGS)
@given(
    n=st.integers(16, 400),
    d=st.integers(1, 5),
    t=st.integers(1, 5),
    rho=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**16),
)
def test_shared_root_delta_equals_direct_root(n, d, t, rho, seed):
    """Shared-root caching (DESIGN.md §9): for ANY uniform 0/1 masks with
    keep-share >= 0.5, ``shared − delta(masked-out rows)`` equals the direct
    per-tree root histogram — the linearity-in-weights identity behind
    ``TreeConfig.shared_root``."""
    import jax

    from repro.core import forest
    from repro.core.histogram import compute_round_histogram

    rng = np.random.default_rng(seed)
    B = 8
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    n_keep = max(1, int(round(n * rho)))
    smask, _ = forest.sample_masks_counts(
        jax.random.PRNGKey(seed % 2**31), n, d, t, n_keep, 1
    )
    zeros = jnp.zeros((t, n), jnp.int32)
    direct = compute_round_histogram(binned, g, h, smask, zeros, 1, B)
    via_delta = compute_round_histogram(
        binned, g, h, smask, zeros, 1, B, root_delta_rows=n - n_keep + 1
    )
    np.testing.assert_allclose(
        np.asarray(via_delta), np.asarray(direct), rtol=1e-3, atol=1e-3
    )


@settings(**SETTINGS)
@given(
    n=st.integers(16, 400),
    seed=st.integers(0, 2**16),
)
def test_histogram_totals_match_sums(n, seed):
    """Row 'count'/'sum_g' marginals equal direct sums regardless of binning."""
    rng = np.random.default_rng(seed)
    B, d = 16, 3
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.asarray((rng.random(n) < 0.7).astype(np.float32))
    hist = compute_histogram(binned, g, h, w, jnp.zeros(n, jnp.int32), 1, B)
    # every feature's bin-marginal is the same masked total
    for f in range(d):
        np.testing.assert_allclose(
            float(hist[0, f, :, 0].sum()), float((g * w).sum()), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            float(hist[0, f, :, 2].sum()), float(w.sum()), rtol=1e-6
        )


@settings(**SETTINGS)
@given(
    rounds=st.integers(1, 60),
    v_min=st.floats(0.05, 0.5),
    span=st.floats(0.01, 0.5),
    k=st.sampled_from([0.25, 0.5, 0.75, 1.0]),
)
def test_dynamic_schedules_bounded_and_monotone(rounds, v_min, span, k):
    v_max = v_min + span
    dec = [dynamic.dynamic_decay(m, rounds, v_min, v_max, k) for m in range(1, rounds + 1)]
    inc = [dynamic.dynamic_increase(m, rounds, v_min, v_max, k) for m in range(1, rounds + 1)]
    eps = 1e-9
    assert all(v_min - eps <= v <= v_max + eps for v in dec + inc)
    assert all(a >= b - eps for a, b in zip(dec, dec[1:]))  # decay monotone down
    assert all(a <= b + eps for a, b in zip(inc, inc[1:]))  # increase monotone up
    # endpoints (k = 1 completes exactly at the last round)
    assert dec[0] == v_max and inc[0] == (v_min if rounds > 1 else v_max)
    if k == 1.0 and rounds > 1:
        assert abs(dec[-1] - v_min) < 1e-6 and abs(inc[-1] - v_max) < 1e-6


def test_dynamic_paper_worked_example():
    """§3.2.2: 11 rounds, 50 -> 15 trees. k=1 ends at 15 in round 11;
    k=0.5 reaches 15 at round 6 and holds through round 11."""
    k1 = [dynamic.dynamic_decay(m, 11, 15, 50, 1.0) for m in range(1, 12)]
    assert abs(k1[0] - 50) < 1e-9 and abs(k1[-1] - 15) < 1e-6
    k05 = [dynamic.dynamic_decay(m, 11, 15, 50, 0.5) for m in range(1, 12)]
    assert abs(k05[5] - 15) < 1e-6  # round 6
    assert all(abs(v - 15) < 1e-6 for v in k05[5:])


@settings(**SETTINGS)
@given(
    rounds=st.integers(1, 30),
    n=st.integers(100, 10_000),
    bins=st.sampled_from([16, 32]),
)
def test_protocol_argmax_never_costlier_than_histogram(rounds, n, bins):
    cfg = FedGBFConfig(rounds=rounds, n_trees_max=5, n_trees_min=2,
                       rho_id_min=0.1, rho_id_max=0.3)
    base = dict(n_samples=n, party_dims=(5, 5), num_bins=bins)
    hist = protocol.run_cost(protocol.ProtocolSpec(**base, aggregation="histogram"), cfg)
    argm = protocol.run_cost(protocol.ProtocolSpec(**base, aggregation="argmax"), cfg)
    assert argm.histograms <= hist.histograms
    assert argm.total <= hist.total


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), parties=st.integers(2, 6))
def test_secure_masks_cancel(seed, parties):
    from repro.federation import secure

    masks = secure.pairwise_masks(seed, parties, (17,))
    np.testing.assert_allclose(np.asarray(masks.sum(0)), np.zeros(17), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    loss=st.sampled_from(["logistic", "squared"]),
    rounds=st.integers(1, 4),
    t_max=st.integers(1, 4),
    t_span=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_packed_predict_bitwise_equals_loop_property(loss, rounds, t_max,
                                                     t_span, seed):
    """PackedEnsemble.predict == legacy per-round loop, bit for bit, for any
    loss and any (dynamic) tree-count schedule (DESIGN.md §3)."""
    from repro.core import boosting
    from repro.core.types import FedGBFConfig, TreeConfig, pack_ensemble

    rng = np.random.default_rng(seed)
    n, d = 200, 4
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y_raw = rng.normal(size=n).astype(np.float32)
    y = jnp.asarray((y_raw > 0).astype(np.float32) if loss == "logistic"
                    else y_raw)
    cfg = FedGBFConfig(
        rounds=rounds, loss=loss,
        n_trees_max=t_max + t_span, n_trees_min=t_max,
        rho_id_min=0.5, rho_id_max=0.9,
        tree=TreeConfig(max_depth=2, num_bins=8),
    )
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(seed % 97))
    x_test = jnp.asarray(rng.normal(size=(83, d)), jnp.float32)
    loop = boosting.predict(model, x_test, impl="loop")
    packed = boosting.predict(pack_ensemble(model), x_test, impl="packed")
    np.testing.assert_array_equal(np.asarray(loop), np.asarray(packed))
