"""Sibling-subtraction histogram pipeline (DESIGN.md §6).

The contract lattice, bottom up:

* histogram ALGEBRA — ``parent == left + right`` additively, and
  ``derive_sibling`` interleaves (left, parent − left) in routing order;
* PROVIDERS — every child provider (generic ``as_child_fn`` adaptation,
  fused Pallas child kernel) agrees with the direct left-child histogram;
* TREES — subtraction-vs-direct parity across the registry backends
  (predictions within float-reassociation tolerance; on this fixed data the
  trees come out structurally identical);
* TRAINING — both engines run the pipeline end-to-end and stay equivalent
  to each other; the leaf fast path is bit-identical to the formulation it
  replaced.

The federated side of the lattice (bit-identity vs centralized with
subtraction on both sides, exact byte reconciliation at half width, the
>= 1.7x measured phase cut) lives in federation/selftest.py, invoked by
tests/test_federation.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, forest, tree
from repro.core.histogram import (
    as_child_fn,
    compute_histogram,
    compute_histogram_onehot,
    derive_sibling,
    leaf_stats,
)
from repro.core.types import FedGBFConfig, TreeConfig


def _case(seed, n, d, B, frontier):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32),
        jnp.asarray(rng.normal(size=n), jnp.float32),
        jnp.asarray(rng.random(n) + 0.05, jnp.float32),
        jnp.asarray(rng.integers(0, 2, n), jnp.float32),
        jnp.asarray(rng.integers(0, frontier, n), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Histogram algebra
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("parents", [1, 2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1])
def test_parent_equals_left_plus_right(parents, seed):
    """Additivity: the parent histogram is exactly the sum of its children's
    (the identity the whole pipeline rests on)."""
    n, d, B = 600, 5, 16
    binned, g, h, w, assign = _case(seed, n, d, B, 2 * parents)
    parent = compute_histogram(binned, g, h, w, assign // 2, parents, B)
    left = as_child_fn(compute_histogram)(binned, g, h, w, assign, parents, B)
    right_w = w * (assign % 2).astype(w.dtype)
    right = compute_histogram(binned, g, h, right_w, assign // 2, parents, B)
    np.testing.assert_allclose(
        np.asarray(left + right), np.asarray(parent), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("parents", [1, 2, 4])
def test_derive_sibling_matches_direct_frontier(parents):
    """derive_sibling(parent, left) == the direct full-frontier histogram,
    up to float reassociation, with children interleaved in routing order."""
    n, d, B = 700, 6, 8
    binned, g, h, w, assign = _case(3, n, d, B, 2 * parents)
    parent = compute_histogram(binned, g, h, w, assign // 2, parents, B)
    left = as_child_fn(compute_histogram)(binned, g, h, w, assign, parents, B)
    derived = derive_sibling(parent, left)
    direct = compute_histogram(binned, g, h, w, assign, 2 * parents, B)
    assert derived.shape == direct.shape
    np.testing.assert_allclose(
        np.asarray(derived), np.asarray(direct), rtol=1e-4, atol=1e-5
    )
    # even nodes ARE the left histograms, bit-for-bit (only right is derived)
    np.testing.assert_array_equal(
        np.asarray(derived[0::2]), np.asarray(left)
    )


def test_child_providers_agree():
    """Generic adaptation of every formulation + the fused Pallas child
    kernel compute the same left-child histogram."""
    from repro.kernels.histogram.ops import compute_histogram_pallas_fused_child

    n, d, B, parents = 700, 9, 16, 4
    binned, g, h, w, assign = _case(5, n, d, B, 2 * parents)
    ref = as_child_fn(compute_histogram)(binned, g, h, w, assign, parents, B)
    oh = as_child_fn(compute_histogram_onehot)(
        binned, g, h, w, assign, parents, B
    )
    pal = compute_histogram_pallas_fused_child(
        binned, g, h, w, assign, parents, B
    )
    np.testing.assert_allclose(np.asarray(oh), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Leaf-statistics fast path
# ---------------------------------------------------------------------------
def test_leaf_stats_bit_identical_to_pseudo_feature_histogram():
    """The direct three-channel segment_sum replaces the old (n, 1)-zeros
    pseudo-feature compute_histogram call bit-for-bit (same segment ids,
    same stacked operand, same reduction)."""
    n, leaves = 900, 8
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    assign = jnp.asarray(rng.integers(0, leaves, n), jnp.int32)
    old = compute_histogram(
        jnp.zeros((n, 1), jnp.int32), g, h, w, assign, leaves, 1
    )[:, 0, 0, :]
    np.testing.assert_array_equal(
        np.asarray(leaf_stats(g, h, w, assign, leaves)), np.asarray(old)
    )


# ---------------------------------------------------------------------------
# Tree / training parity across registry backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["local", "local-pallas"])
@pytest.mark.parametrize("seed", [0, 2])
def test_subtraction_vs_direct_tree_parity(backend, seed):
    """Trees built with the subtraction pipeline agree with the direct
    reference oracle on every registry backend: identical routing behaviour
    within float-reassociation tolerance (structural equality asserted too —
    on CPU with fixed data the argmax never lands on a reassociation-size
    tie for these seeds)."""
    from repro.core.backend import get_backend

    n, d, B = 800, 7, 16
    rng = np.random.default_rng(seed)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    fm = jnp.ones(d, bool)
    bk = get_backend(backend)

    cfg_d = TreeConfig(max_depth=3, num_bins=B, hist_subtraction=False)
    cfg_s = TreeConfig(max_depth=3, num_bins=B, hist_subtraction=True)
    t_d, a_d = tree.build_tree(binned, g, h, w, fm, cfg_d, backend=bk)
    t_s, a_s = tree.build_tree(binned, g, h, w, fm, cfg_s, backend=bk)

    np.testing.assert_array_equal(np.asarray(t_d.feature), np.asarray(t_s.feature))
    np.testing.assert_array_equal(
        np.asarray(t_d.threshold), np.asarray(t_s.threshold)
    )
    np.testing.assert_array_equal(np.asarray(a_d), np.asarray(a_s))
    np.testing.assert_allclose(
        np.asarray(t_d.leaf_weight), np.asarray(t_s.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )


def test_subtraction_forest_and_engines_end_to_end():
    """Full training with hist_subtraction on: scan and loop engines stay
    metric-equivalent to each other, and the end metrics track the direct
    pipeline within the §5/§6 tolerance class."""
    rng = np.random.default_rng(11)
    n, d = 1200, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] - 0.5 * x[:, 1] + rng.normal(0, 0.5, n) > 0).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    base = FedGBFConfig(
        rounds=3, n_trees_max=3, n_trees_min=2, rho_id_min=0.5, rho_id_max=0.8,
        tree=TreeConfig(max_depth=3, num_bins=16, hist_subtraction=False),
    )
    import dataclasses

    sub = dataclasses.replace(
        base, tree=dataclasses.replace(base.tree, hist_subtraction=True)
    )
    _, h_scan = boosting.train_fedgbf(x, y, sub, jax.random.PRNGKey(0))
    _, h_loop = boosting.train_fedgbf(x, y, sub, jax.random.PRNGKey(0),
                                      engine="loop")
    for a, b in zip(h_scan.train, h_loop.train):
        for k in a:
            assert abs(a[k] - b[k]) <= 1e-5, (k, a[k], b[k])
    _, h_direct = boosting.train_fedgbf(x, y, base, jax.random.PRNGKey(0))
    for a, b in zip(h_scan.train, h_direct.train):
        for k in a:
            assert abs(a[k] - b[k]) <= 5e-3, (k, a[k], b[k])


def test_subtraction_unsplittable_frontier():
    """Degenerate case: a level with no split keeps every sample in the left
    child, so the derived right siblings are all-zero histograms — the tree
    must match the direct pipeline's split-free structure exactly."""
    n, d, B = 128, 3, 8
    binned = jnp.zeros((n, d), jnp.int32)
    g = jnp.asarray(np.random.default_rng(0).normal(size=n), jnp.float32)
    ones = jnp.ones(n, jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=B, hist_subtraction=True)
    tr, assign = tree.build_tree(binned, g, ones, ones, jnp.ones(d, bool), cfg)
    assert np.all(np.asarray(tr.feature) == -1)
    assert np.all(np.asarray(assign) == 0)


def test_masks_compose_with_subtraction():
    """Weighted (GOSS-style) sample masks ride the same weight channel the
    child provider left-masks — forest build agrees with the direct path."""
    rng = np.random.default_rng(13)
    n, d, B = 600, 5, 16
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones(n, jnp.float32)
    n_top, n_rand = forest.goss_counts(n, 0.4, 0.5)
    smask, fmask = forest.goss_masks(
        jax.random.PRNGKey(3), g, d, 3, n_top, n_rand, d
    )
    cfg_d = TreeConfig(max_depth=3, num_bins=B, hist_subtraction=False)
    cfg_s = TreeConfig(max_depth=3, num_bins=B, hist_subtraction=True)
    trees_d, pred_d = forest.build_forest(binned, g, h, smask, fmask, cfg_d)
    trees_s, pred_s = forest.build_forest(binned, g, h, smask, fmask, cfg_s)
    np.testing.assert_array_equal(
        np.asarray(trees_d.feature), np.asarray(trees_s.feature)
    )
    np.testing.assert_allclose(
        np.asarray(pred_d), np.asarray(pred_s), rtol=1e-5, atol=1e-6
    )
