"""Objective registry parity suite (DESIGN.md §11).

Single-device contracts of the K-channel objective layer: the registry
itself, the K=1 softmax == logistic reduction, per-objective training
parity across the local backends and engines, the squared-checkpoint
serving regression, the losses.py deprecation shims, and the gradient-less
party-local mode (which needs no device mesh — its whole point is that
nothing crosses a party boundary).  The federated axes (vfl-histogram,
q8, async, sharded × softmax3/quantile) run in the multi-device selftest
subprocess (tests/test_federation.py -> repro.federation.selftest).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, losses
from repro.core import objective as objective_mod
from repro.core.types import FedGBFConfig, TreeConfig

OBJECTIVES = ["logistic", "squared", "softmax3", "quantile", "quantile@0.9"]


def _labels(obj, rng, n):
    k = obj.n_classes
    if k > 1:
        return jnp.asarray(rng.integers(0, k, n), jnp.float32)
    if obj.name.startswith("quantile") or obj.name == "squared":
        return jnp.asarray(rng.normal(size=n), jnp.float32)
    return jnp.asarray(rng.integers(0, 2, n), jnp.float32)


@pytest.fixture(scope="module")
def toy():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(400, 6)).astype(np.float32)
    return jnp.asarray(x), rng


# ---------------------------------------------------------------- registry


def test_registry_shapes_and_stats():
    n = 32
    for name in OBJECTIVES:
        obj = objective_mod.get_objective(name)
        y = jnp.zeros(n)
        g, h = obj.grad_hess(y, obj.init_raw(n))
        expect = (n,) if obj.n_classes == 1 else (n, obj.n_classes)
        assert g.shape == expect and h.shape == expect, name
        assert objective_mod.num_stats(obj.n_classes) == 2 * obj.n_classes + 1
        assert jnp.isfinite(obj.loss_value(y, obj.init_raw(n)))


def test_get_objective_is_cached_singleton():
    assert objective_mod.get_objective("softmax3") is (
        objective_mod.get_objective("softmax3")
    )
    with pytest.raises(ValueError, match="unknown objective"):
        objective_mod.get_objective("not-an-objective")


def test_softmax_hessian_nonnegative_property():
    """p(1-p) per class: every per-class hessian entry must be >= 0 for any
    margin — the split-gain denominator and leaf weights rely on it."""
    rng = np.random.default_rng(11)
    obj = objective_mod.get_objective("softmax4")
    y = jnp.asarray(rng.integers(0, 4, 256), jnp.float32)
    margin = jnp.asarray(rng.normal(scale=4.0, size=(256, 4)), jnp.float32)
    _, h = obj.grad_hess(y, margin)
    assert (h >= 0).all()
    # rows of the activation are probability vectors
    p = obj.activation(margin)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)


def test_quantile_constant_hessian_and_pinball():
    obj = objective_mod.get_objective("quantile@0.9")
    y = jnp.asarray([1.0, -2.0, 0.5])
    pred = jnp.asarray([0.0, 0.0, 1.0])
    g, h = obj.grad_hess(y, pred)
    # gradient of pinball: -(alpha) under, (1-alpha) over
    np.testing.assert_allclose(np.asarray(g), [-0.9, 0.1, 0.1], atol=1e-6)
    assert (h == h[0]).all() and h[0] > 0
    # pinball loss value: mean(alpha*max(r,0) + (1-alpha)*max(-r,0))
    r = np.asarray(y - pred)
    want = np.mean(np.where(r > 0, 0.9 * r, -0.1 * r))
    np.testing.assert_allclose(float(obj.loss_value(y, pred)), want, atol=1e-6)


def test_softmax1_is_logistic_bit_exact():
    """K=1 softmax aliases the logistic formulas so the K-channel machinery
    has an exact scalar reduction."""
    rng = np.random.default_rng(5)
    y = jnp.asarray(rng.integers(0, 2, 200), jnp.float32)
    margin = jnp.asarray(rng.normal(size=200), jnp.float32)
    s1 = objective_mod.get_objective("softmax1")
    lg = objective_mod.get_objective("logistic")
    gs, hs = s1.grad_hess(y, margin)
    gl, hl = lg.grad_hess(y, margin)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(gl))
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(hl))
    assert float(s1.loss_value(y, margin)) == float(lg.loss_value(y, margin))


# ------------------------------------------------------- deprecation shims


def test_losses_shims_delegate_to_registry():
    rng = np.random.default_rng(7)
    y = jnp.asarray(rng.integers(0, 2, 100), jnp.float32)
    margin = jnp.asarray(rng.normal(size=100), jnp.float32)
    for name in ("logistic", "squared"):
        obj = objective_mod.get_objective(name)
        g_s, h_s = losses.grad_hess(name, y, margin)
        g_o, h_o = obj.grad_hess(y, margin)
        np.testing.assert_array_equal(np.asarray(g_s), np.asarray(g_o))
        np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_o))
        assert float(losses.loss_value(name, y, margin)) == float(
            obj.loss_value(y, margin)
        )


# ------------------------------------------------------------ training parity


@pytest.mark.parametrize("name", OBJECTIVES)
def test_train_scan_equals_loop(toy, name):
    x, rng = toy
    obj = objective_mod.get_objective(name)
    y = _labels(obj, rng, x.shape[0])
    cfg = FedGBFConfig(
        rounds=3, n_trees_max=2, n_trees_min=2, rho_id_min=0.5,
        rho_id_max=0.8, loss=name, tree=TreeConfig(max_depth=3, num_bins=16),
    )
    from repro.core.types import pack_ensemble

    m_scan, h_scan = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), engine="scan"
    )
    m_loop, h_loop = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), engine="loop"
    )
    p_scan, p_loop = pack_ensemble(m_scan), pack_ensemble(m_loop)
    np.testing.assert_array_equal(
        np.asarray(p_scan.feature), np.asarray(p_loop.feature)
    )
    np.testing.assert_allclose(
        np.asarray(p_scan.leaf_weight), np.asarray(p_loop.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    assert h_scan.train[-1].keys() == h_loop.train[-1].keys()


@pytest.mark.parametrize("name", ["logistic", "softmax3", "quantile@0.9"])
def test_train_pallas_matches_local(toy, name):
    """The channel-folded fused kernel must train the same model as the XLA
    segment path for scalar AND K-channel objectives."""
    from repro.core import backend as backend_mod

    x, rng = toy
    obj = objective_mod.get_objective(name)
    y = _labels(obj, rng, x.shape[0])
    cfg = FedGBFConfig(
        rounds=2, n_trees_max=2, n_trees_min=2, rho_id_min=0.6,
        rho_id_max=0.8, loss=name, tree=TreeConfig(max_depth=3, num_bins=16),
    )
    from repro.core.types import pack_ensemble

    m_ref, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    m_pal, _ = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0),
        backend=backend_mod.get_backend("local-pallas"),
    )
    p_ref, p_pal = pack_ensemble(m_ref), pack_ensemble(m_pal)
    np.testing.assert_array_equal(
        np.asarray(p_ref.feature), np.asarray(p_pal.feature)
    )
    np.testing.assert_allclose(
        np.asarray(p_ref.leaf_weight), np.asarray(p_pal.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )


def test_multiclass_training_reduces_loss_and_predicts_K(toy):
    x, rng = toy
    obj = objective_mod.get_objective("softmax3")
    y = _labels(obj, rng, x.shape[0])
    cfg = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2, rho_id_min=0.5,
        rho_id_max=0.8, loss="softmax3",
        tree=TreeConfig(max_depth=3, num_bins=16),
    )
    model, hist = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    assert hist.train[-1]["loss"] < hist.train[0]["loss"]
    margin = boosting.predict(model, x)
    assert margin.shape == (x.shape[0], 3)
    prob = boosting.predict_proba(model, x)
    np.testing.assert_allclose(np.asarray(prob.sum(-1)), 1.0, atol=1e-5)


# --------------------------------------------------------- serving regression


def test_squared_checkpoint_not_sigmoided(toy, tmp_path):
    """Regression: serving used to hard-code sigmoid for anything it loaded
    with loss == 'logistic' and pass margins otherwise — but the activation
    must come from the registry keyed by the checkpoint's stored objective.
    A squared-loss checkpoint's served scores must equal raw margins."""
    from repro.checkpoint import io as ckpt_io
    from repro.core.types import pack_ensemble
    from repro.launch import serve_fedgbf

    x, rng = toy
    y = jnp.asarray(rng.normal(size=x.shape[0]), jnp.float32)
    cfg = FedGBFConfig(
        rounds=2, n_trees_max=2, n_trees_min=2, rho_id_min=0.6,
        rho_id_max=0.8, loss="squared",
        tree=TreeConfig(max_depth=3, num_bins=16),
    )
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "sq_ckpt")
    ckpt_io.save_ensemble(path, pack_ensemble(model))
    loaded = ckpt_io.load_ensemble(path)
    assert loaded.loss == "squared"
    scores, _ = serve_fedgbf.score_stream(loaded, np.asarray(x), batch_size=128)
    margins = np.asarray(boosting.predict(loaded, x))
    np.testing.assert_allclose(scores, margins, atol=1e-6)
    # a sigmoided output would be confined to (0, 1); raw margins are not
    assert scores.min() < 0 or scores.max() > 1


def test_softmax_checkpoint_serves_probability_rows(toy, tmp_path):
    from repro.checkpoint import io as ckpt_io
    from repro.core.types import pack_ensemble
    from repro.launch import serve_fedgbf

    x, rng = toy
    obj = objective_mod.get_objective("softmax3")
    y = _labels(obj, rng, x.shape[0])
    cfg = FedGBFConfig(
        rounds=2, n_trees_max=2, n_trees_min=2, rho_id_min=0.6,
        rho_id_max=0.8, loss="softmax3",
        tree=TreeConfig(max_depth=3, num_bins=16),
    )
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "sm_ckpt")
    ckpt_io.save_ensemble(path, pack_ensemble(model))
    loaded = ckpt_io.load_ensemble(path)
    scores, _ = serve_fedgbf.score_stream(loaded, np.asarray(x), batch_size=128)
    assert scores.shape == (x.shape[0], 3)
    np.testing.assert_allclose(scores.sum(-1), 1.0, atol=1e-5)


# ------------------------------------------------------------- gradient-less


def test_gradientless_party_local(toy):
    """Gradient-less mode on a single device: rate fit improves the global
    loss, trees stay party-local, and the meter records ONLY margin/rate
    phases — priced exactly by gradientless.wire_cost."""
    from repro.federation import compress, gradientless

    x, rng = toy
    y = jnp.asarray(rng.integers(0, 2, x.shape[0]), jnp.float32)
    cfg = FedGBFConfig(
        rounds=2, n_trees_max=2, n_trees_min=2, rho_id_min=0.6,
        rho_id_max=0.8, tree=TreeConfig(max_depth=3, num_bins=16),
    )
    meter = compress.MessageMeter()
    packed, info = gradientless.train_gradientless(
        x, y, cfg, jax.random.PRNGKey(0), num_parties=2, meter=meter,
    )
    assert info["loss_after"] <= info["loss_before"] + 1e-6
    measured = meter.phase_totals()
    assert set(measured) == {"tree_margins", "tree_scales"}
    predicted = gradientless.wire_cost(x.shape[0], info["tree_counts"])
    assert measured["tree_margins"] == predicted["tree_margins"]
    assert measured["tree_scales"] == predicted["tree_scales"]
    assert predicted["histograms"] == 0 and predicted["grad_broadcast"] == 0
    # the packed model predicts on the FULL feature matrix
    margin = boosting.predict(packed, x)
    assert margin.shape == (x.shape[0],)
