"""Registry-parameterized checkpoint round-trip (guards sidecar drift).

Every registered backend name — including the compressed-transport VFL
backends — trains a tiny model, packs, saves, reloads, and predicts
bit-identically.  New backends land in the registry (DESIGN.md §1), so this
sweep catches any whose models stop round-tripping through the packed
checkpoint sidecar (checkpoint/io.py) the moment they are registered.

VFL backends run on a degenerate 1-party mesh: one CPU device drives the
full shard_map + transport code path (multi-party equivalence is
federation/selftest.py's job).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import backend as backend_mod
from repro.core import boosting
from repro.core.types import FedGBFConfig, PackedEnsemble, TreeConfig, pack_ensemble

TREE = TreeConfig(max_depth=2, num_bins=8)
CFG = FedGBFConfig(rounds=2, n_trees_max=3, n_trees_min=2,
                   rho_id_min=0.5, rho_id_max=0.8, tree=TREE)


def _build(name):
    if name.startswith("vfl"):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return backend_mod.get_backend(name, mesh=mesh, tree=TREE)
    return backend_mod.get_backend(name)


def _data(n=300, d=4, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = ((x[:, 0] - 0.6 * x[:, 1] + rng.normal(0, 0.4, n)) > 0).astype(np.float32)
    x_test = rng.normal(size=(97, d)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(x_test)


@pytest.mark.parametrize("name", backend_mod.available_backends())
def test_checkpoint_roundtrip_every_backend(name, tmp_path):
    from repro.compat import use_mesh

    x, y, x_test = _data()
    backend = _build(name)
    ctx = use_mesh(jax.make_mesh((1, 1), ("data", "model"))) \
        if name.startswith("vfl") else None
    if ctx is not None:
        with ctx:
            model, _ = boosting.train_fedgbf(x, y, CFG, jax.random.PRNGKey(0),
                                             backend=backend)
    else:
        model, _ = boosting.train_fedgbf(x, y, CFG, jax.random.PRNGKey(0),
                                         backend=backend)

    packed = pack_ensemble(model)
    path = str(tmp_path / f"ckpt-{name}")
    ckpt_io.save_ensemble(path, packed)
    loaded = ckpt_io.load_ensemble(path)
    assert isinstance(loaded, PackedEnsemble)
    # sidecar metadata survives exactly
    assert loaded.round_offsets == packed.round_offsets
    assert loaded.loss == packed.loss
    assert loaded.max_depth == packed.max_depth
    assert loaded.learning_rate == packed.learning_rate
    np.testing.assert_array_equal(np.asarray(loaded.tree_scale),
                                  np.asarray(packed.tree_scale))
    # and prediction is bit-identical through the round-trip
    np.testing.assert_array_equal(
        np.asarray(boosting.predict(packed, x_test)),
        np.asarray(boosting.predict(loaded, x_test)),
    )


def test_checkpoint_roundtrip_goss_config(tmp_path):
    """GOSS is a config knob, not a backend: its models round-trip too."""
    x, y, x_test = _data(seed=1)
    cfg = dataclasses.replace(CFG, sampling="goss")
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt-goss")
    ckpt_io.save_ensemble(path, model)
    loaded = ckpt_io.load_ensemble(path)
    np.testing.assert_array_equal(
        np.asarray(boosting.predict(model, x_test, impl="loop")),
        np.asarray(boosting.predict(loaded, x_test)),
    )
