"""Dry-run smoke (deliverable e, reduced): lowers + compiles train/prefill/
decode for six smoke archs on an 8-device forced mesh in a subprocess."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_selftest_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun_selftest"],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-2000:]}"
    assert "DRYRUN SELFTEST PASSED" in out.stdout


def test_shape_applicability_table():
    from repro.configs import ARCH_IDS
    from repro.launch import shapes

    runs = {a for a in ARCH_IDS if shapes.applicable(a, "long_500k")[0]}
    assert runs == {"zamba2-7b", "rwkv6-7b", "gemma2-2b", "mixtral-8x22b"}
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shapes.applicable(a, s)[0]


def test_roofline_collective_parser():
    from repro.tools.roofline import parse_collectives

    hlo = """
  %ag = bf16[16,1024,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%sum
  %a2a = f32[8,32]{1,0} all-to-all(%z), dimensions={0}
  %cp = u32[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %rs = bf16[512]{0} reduce-scatter(%v), dimensions={0}, to_apply=%sum
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind == {
        "all-gather": 1, "all-reduce": 1, "all-to-all": 1,
        "collective-permute": 1, "reduce-scatter": 1,
    }
    assert stats.bytes_by_kind["all-gather"] == 16 * 1024 * 128 * 2
    assert stats.bytes_by_kind["all-reduce"] == 256 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 512 * 2
