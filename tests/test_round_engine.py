"""Round-native forest engine (core/tree.py::build_round, DESIGN.md §9).

The contract lattice:

* ROUND == PER-TREE — ``build_round`` is bit-identical to vmapping the
  T = 1 special case (``build_tree``) over the tree axis, for every local
  registry backend, subtraction on and off (the federated twin of this
  check lives in federation/selftest.py);
* COMPACTION — with a ``max_active_nodes`` budget the trees stay
  bit-identical to the uncompacted build whenever the live frontier fits
  the budget, and remain structurally consistent (routing == prediction)
  when the budget truncates;
* SHARED ROOT — ``shared − delta`` equals the direct per-tree root
  histogram (float-reassociation tolerance; the hypothesis-property twin
  lives in tests/test_properties.py), end-to-end training stays in the
  §5/§6 tolerance class, and the level-0 row volume drops from ``T·n`` to
  ``n + T·rdr`` (asserted through the trace-time pass meter).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, forest, histogram as hist_mod, tree
from repro.core.backend import get_backend
from repro.core.types import FedGBFConfig, TreeConfig


def _case(seed=0, n=700, d=7, B=16, T=4, rho=0.8):
    rng = np.random.default_rng(seed)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    smask, fmask = forest.sample_masks(
        jax.random.PRNGKey(seed + 1), n, d, T, rho, 0.9
    )
    return binned, g, h, smask, fmask


def _assert_trees_equal(a, b, leaf_tol=0.0):
    np.testing.assert_array_equal(np.asarray(a.feature), np.asarray(b.feature))
    np.testing.assert_array_equal(
        np.asarray(a.threshold), np.asarray(b.threshold)
    )
    if leaf_tol:
        np.testing.assert_allclose(
            np.asarray(a.leaf_weight), np.asarray(b.leaf_weight),
            rtol=leaf_tol, atol=leaf_tol,
        )
    else:
        np.testing.assert_array_equal(
            np.asarray(a.leaf_weight), np.asarray(b.leaf_weight)
        )


# ---------------------------------------------------------------------------
# Round == per-tree vmap
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["local", "local-pallas"])
@pytest.mark.parametrize("subtraction", [False, True])
def test_build_round_bit_identical_to_per_tree_vmap(backend, subtraction):
    """The round engine must reproduce the per-tree path bit-for-bit on the
    non-lossy backends (acceptance bar of the round refactor)."""
    binned, g, h, smask, fmask = _case()
    cfg = TreeConfig(max_depth=3, num_bins=16, hist_subtraction=subtraction)
    bk = get_backend(backend)
    trees_r, assign_r = tree.build_round(
        binned, g, h, smask, fmask, cfg, backend=bk
    )
    trees_v, assign_v = jax.vmap(
        lambda sm, fm: tree.build_tree(binned, g, h, sm, fm, cfg, backend=bk)
    )(smask, fmask)
    _assert_trees_equal(trees_r, trees_v, leaf_tol=1e-6)
    np.testing.assert_array_equal(np.asarray(assign_r), np.asarray(assign_v))


def test_build_tree_is_t1_special_case():
    """``build_tree`` delegates to the round engine with a singleton tree
    axis — same arrays, no leading dim."""
    binned, g, h, smask, fmask = _case(T=1)
    cfg = TreeConfig(max_depth=3, num_bins=16)
    tr, assign = tree.build_tree(binned, g, h, smask[0], fmask[0], cfg)
    trees, assign_r = tree.build_round(binned, g, h, smask, fmask, cfg)
    assert tr.feature.shape == (cfg.num_internal,)
    np.testing.assert_array_equal(np.asarray(tr.feature),
                                  np.asarray(trees.feature[0]))
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(assign_r[0]))


def test_forest_build_matches_round():
    """forest.build_forest rides the round engine: per-tree predictions are
    the leaf gathers of the round assignment."""
    binned, g, h, smask, fmask = _case()
    cfg = TreeConfig(max_depth=3, num_bins=16)
    trees, per_tree = forest.build_forest_per_tree(
        binned, g, h, smask, fmask, cfg
    )
    trees_r, assign_r = tree.build_round(binned, g, h, smask, fmask, cfg)
    _assert_trees_equal(trees, trees_r)
    np.testing.assert_array_equal(
        np.asarray(per_tree),
        np.asarray(jnp.take_along_axis(trees_r.leaf_weight, assign_r, axis=1)),
    )


# ---------------------------------------------------------------------------
# Frontier compaction (max_depth > 3)
# ---------------------------------------------------------------------------
def _live_counts(trees, assign, smask, max_depth):
    """Host-side live-node counts per level of an (uncompacted) build."""
    feat = np.asarray(trees.feature)
    T = feat.shape[0]
    counts = []
    for level in range(1, max_depth):
        width = 2 ** level
        off = width - 1
        parent = feat[:, (2 ** (level - 1) - 1):off]     # (T, width/2)
        parent_split = np.repeat(parent >= 0, 2, axis=1)  # (T, width)
        # recover the level assignment by walking the stored tree
        live = np.zeros((T, width), bool)
        for t in range(T):
            idx = np.zeros(assign.shape[1], np.int64)
            a = np.asarray(assign[t])
            # leaf assignment >> (max_depth - level) is the level-node id
            node = a >> (max_depth - level)
            w = np.asarray(smask[t]) > 0
            present = np.zeros(width, bool)
            present[np.unique(node[w])] = True
            live[t] = present & parent_split[t]
        counts.append(live.sum(axis=1).max())
    return counts


@pytest.mark.parametrize("max_depth", [4, 5])
@pytest.mark.parametrize("subtraction", [False, True])
def test_compaction_bit_identical_when_budget_fits(max_depth, subtraction):
    """With a budget covering the actual live frontier, the compacted build
    is bit-identical to the uncompacted one (dead-node masking provably
    changes nothing: empty nodes and no-split descendants cannot split)."""
    # gamma + min_child_weight prune weak splits so deep frontiers stay
    # sparse (live <= 4 on this seed, verified below)
    binned, g, h, smask, fmask = _case(seed=3, n=500)
    cfg = TreeConfig(max_depth=max_depth, num_bins=16, gamma=2.0,
                     min_child_weight=20.0, hist_subtraction=subtraction)
    trees_u, assign_u = tree.build_round(binned, g, h, smask, fmask, cfg)
    live_max = max(_live_counts(trees_u, assign_u, smask, max_depth))
    budget = int(max(2, live_max))
    assert budget < 2 ** (max_depth - 1), (
        "fixture drifted: frontier too dense for a meaningful budget"
    )
    cfg_b = dataclasses.replace(cfg, max_active_nodes=budget)
    trees_b, assign_b = tree.build_round(binned, g, h, smask, fmask, cfg_b)
    _assert_trees_equal(trees_u, trees_b)
    np.testing.assert_array_equal(np.asarray(assign_u), np.asarray(assign_b))


@pytest.mark.parametrize("budget", [2, 4])
def test_compaction_truncation_stays_consistent(budget):
    """A budget below the live frontier truncates (overflow nodes fall
    through unsplit) but the trees stay structurally valid: stored routing
    equals traversal, leaves carry the routed samples."""
    binned, g, h, smask, fmask = _case(seed=3)
    cfg = TreeConfig(max_depth=5, num_bins=16, max_active_nodes=budget)
    trees, assign = tree.build_round(binned, g, h, smask, fmask, cfg)
    per = jnp.take_along_axis(trees.leaf_weight, assign, axis=1)
    pred = tree.predict_trees(trees, binned, cfg.max_depth)
    np.testing.assert_allclose(np.asarray(per), np.asarray(pred))
    # the per-level split count never exceeds the budget
    feat = np.asarray(trees.feature)
    for level in range(5):
        off, width = 2 ** level - 1, 2 ** level
        split_nodes = (feat[:, off:off + width] >= 0).sum(axis=1)
        assert (split_nodes <= min(width, budget)).all()


def test_compaction_depth45_training_end_to_end():
    """Deep-tree training under compaction: both engines run and agree."""
    rng = np.random.default_rng(7)
    n, d = 900, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] - 0.5 * x[:, 1] + rng.normal(0, 0.5, n) > 0).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    cfg = FedGBFConfig(
        rounds=3, n_trees_max=3, n_trees_min=2, rho_id_min=0.5,
        rho_id_max=0.8,
        tree=TreeConfig(max_depth=4, num_bins=16, max_active_nodes=4),
    )
    _, h_scan = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    _, h_loop = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                      engine="loop")
    for a, b in zip(h_scan.train, h_loop.train):
        for k in a:
            assert abs(a[k] - b[k]) <= 1e-5, (k, a[k], b[k])


# ---------------------------------------------------------------------------
# Shared-root caching
# ---------------------------------------------------------------------------
def test_shared_root_delta_matches_direct_root_histogram():
    """``shared − delta(masked-out rows)`` == the direct per-tree root
    histogram, within float-reassociation tolerance."""
    binned, g, h, smask, _ = _case(rho=0.8)
    T, n = smask.shape
    rdr = int(n - np.asarray(smask).sum(axis=1).min())
    zeros = jnp.zeros((T, n), jnp.int32)
    direct = hist_mod.compute_round_histogram(binned, g, h, smask, zeros, 1, 16)
    delta = hist_mod.compute_round_histogram(
        binned, g, h, smask, zeros, 1, 16, root_delta_rows=rdr
    )
    np.testing.assert_allclose(
        np.asarray(delta), np.asarray(direct), rtol=1e-4, atol=1e-3
    )


def test_shared_root_level0_pass_volume():
    """The level-0 row volume drops from T·n to n + T·rdr: asserted through
    the trace-time pass meter (shape-determined, so the check is exact)."""
    binned, g, h, smask, fmask = _case()
    T, n = smask.shape
    cfg = TreeConfig(max_depth=3, num_bins=16)

    def probe(rdr):
        hist_mod.PASS_METER = []
        try:
            jax.eval_shape(
                lambda: tree.build_round(binned, g, h, smask, fmask, cfg,
                                         root_delta_rows=rdr)
            )
            return [e for e in hist_mod.PASS_METER]
        finally:
            hist_mod.PASS_METER = None

    direct = [e for e in probe(0) if e["tag"] == "round"]
    # level 0 is the first record: T trees over all n rows
    assert direct[0] == {"tag": "round", "rows": n, "trees": T}
    rdr = 140
    entries = probe(rdr)
    shared = [e for e in entries if e["tag"] == "round"][0]
    delta = [e for e in entries if e["tag"] == "root_delta"][0]
    assert shared == {"tag": "round", "rows": n, "trees": 1}
    assert delta == {"tag": "root_delta", "rows": rdr, "trees": T}
    # the crossover's win: n + T·rdr < T·n at rho >= 0.5
    assert n + T * rdr < T * n


def test_shared_root_training_tolerance_and_crossover():
    """End-to-end: shared_root training tracks the direct pipeline within
    the §5/§6 tolerance class; rounds below the rho crossover take the
    direct path (exercised via a mixed schedule)."""
    rng = np.random.default_rng(11)
    n, d = 1200, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] - 0.5 * x[:, 1] + rng.normal(0, 0.5, n) > 0).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    base = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2,
        rho_id_min=0.3, rho_id_max=0.9,   # crosses the 0.5 threshold
        tree=TreeConfig(max_depth=3, num_bins=16),
    )
    shared = dataclasses.replace(
        base, tree=dataclasses.replace(base.tree, shared_root=True)
    )
    _, h_dir = boosting.train_fedgbf(x, y, base, jax.random.PRNGKey(0))
    m_shared, h_shared = boosting.train_fedgbf(x, y, shared,
                                               jax.random.PRNGKey(0))
    m_loop, h_loop = boosting.train_fedgbf(x, y, shared, jax.random.PRNGKey(0),
                                           engine="loop")
    for a, b in zip(h_shared.train, h_dir.train):
        for k in a:
            assert abs(a[k] - b[k]) <= 5e-3, (k, a[k], b[k])
    # scan == loop even when a constant-width segment spans the rho 0.5
    # crossover: segments additionally split at the eligibility boundary,
    # so every round makes the loop engine's exact delta-vs-direct choice,
    # and surplus (bucketed) buffer rows carry weight 0 — the engines'
    # trees are bit-identical, not merely close.
    for fs, fl in zip(m_shared.forests, m_loop.forests):
        np.testing.assert_array_equal(np.asarray(fs.feature),
                                      np.asarray(fl.feature))
    for a, b in zip(h_shared.train, h_loop.train):
        for k in a:
            assert abs(a[k] - b[k]) <= 1e-5, (k, a[k], b[k])


def test_root_delta_rows_crossover_rule():
    """The schedule-driven crossover: delta only at rho >= 0.5 and uniform
    sampling; GOSS always routes direct.  Buffer widths bucket to powers of
    two (surplus rows are weight-0 inert) so a dynamic rho schedule compiles
    O(log n) programs, not one per round."""
    tree_cfg = TreeConfig(shared_root=True)
    cfg = FedGBFConfig(tree=tree_cfg)
    assert boosting._root_delta_rows(cfg, 1000, 0.8) == 256  # 200 -> pow2
    assert boosting._root_delta_rows(cfg, 1000, 0.4) == 0
    assert boosting._root_delta_rows(cfg, 1000, 1.0) == 1  # minimal buffer
    goss = dataclasses.replace(cfg, sampling="goss")
    assert boosting._root_delta_rows(goss, 1000, 0.8) == 0
    off = FedGBFConfig(tree=TreeConfig())
    assert boosting._root_delta_rows(off, 1000, 0.8) == 0
    # distinct rho values collapse into few static widths
    widths = {boosting._root_delta_rows(cfg, 1000, r)
              for r in (0.6, 0.65, 0.7, 0.75, 0.8, 0.9)}
    assert widths == {512, 256, 128}
    assert boosting._delta_bucket(700, 1000) == 1000  # capped at n
