"""Row-sharding invariants (DESIGN.md §8) + the bit-packed routing wire.

The data-axis psum is exact because histograms, leaf statistics and
shared-root deltas are all plain sums over rows — any partition of the
sample axis, even and uneven alike, must reproduce the single-host values.
The checks here assert that *bit-identically*: inputs are drawn from an
exact-representable float grid (small multiples of a power of two, bounded
counts), so every partial sum is exact in float32 and the shard
decomposition cannot perturb a single bit regardless of association order.
The federated twin of these checks (real shard_map programs over a
(data, model) mesh) lives in federation/selftest.py.

Each invariant runs two ways: a deterministic parametrized sweep over shard
counts {1, 2, 4}, uneven splits and GOSS weight masks (always on, so the
tier-1 suite covers the contract even without hypothesis), and a hypothesis
property over the same space when the package is installed.

The id_partition bit-packing (federation/aggregator.py) rides along: the
pack/unpack round-trip, the carry-free psum-equals-OR property under
disjoint party ownership, and the shard-aware wire-model arithmetic.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import histogram as hist_mod
from repro.federation import aggregator, protocol

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 container has no hypothesis; sweeps still run
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)
SETTINGS = dict(max_examples=20, deadline=None)

#: weight grid: {0, 1} plain masks plus GOSS-style power-of-two
#: amplification factors — exact under float32 multiplication.
GOSS_WEIGHTS = np.array([0.0, 0.5, 1.0, 2.0, 4.0], np.float32)

#: deterministic sweep over the property space: (shards, goss, seed)
SWEEP = [(1, False, 0), (2, False, 1), (2, True, 2), (4, False, 3),
         (4, True, 4)]


def _exact_case(rng, n, d, T, B, goss):
    """Inputs on an exact float grid: g, h are multiples of 1/8 in
    [-16, 16], weights are powers of two (or 0/1 masks) — all partial
    float32 sums over <= a few hundred rows are exact, so summation
    order is provably irrelevant and equality checks can be bitwise."""
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.integers(-128, 129, n) / 8.0, jnp.float32)
    h = jnp.asarray(rng.integers(0, 129, n) / 8.0, jnp.float32)
    if goss:
        w = jnp.asarray(rng.choice(GOSS_WEIGHTS, (T, n)))
    else:
        w = jnp.asarray(rng.integers(0, 2, (T, n)), jnp.float32)
    return binned, g, h, w


def _uneven_bounds(rng, n, shards):
    """Random shard boundaries — deliberately uneven, no empty shards."""
    if shards == 1:
        return [0, n]
    cuts = np.sort(rng.choice(np.arange(1, n), size=shards - 1, replace=False))
    return [0, *cuts.tolist(), n]


def _check_sharded_histogram(n, d, T, nodes, shards, goss, seed):
    """Sum of per-shard round histograms == the single-host histogram,
    BIT-identical, for any shard count and uneven row split — the invariant
    the data-axis psum in the sharded backends relies on."""
    rng = np.random.default_rng(seed)
    B = 8
    binned, g, h, w = _exact_case(rng, n, d, T, B, goss)
    assign = jnp.asarray(rng.integers(0, nodes, (T, n)), jnp.int32)
    full = hist_mod.compute_round_histogram(binned, g, h, w, assign, nodes, B)
    bounds = _uneven_bounds(rng, n, shards)
    acc = jnp.zeros_like(full)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        acc = acc + hist_mod.compute_round_histogram(
            binned[lo:hi], g[lo:hi], h[lo:hi], w[:, lo:hi],
            assign[:, lo:hi], nodes, B,
        )
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(full))


def _check_sharded_leaf_stats(n, T, leaves, shards, goss, seed):
    """Per-shard leaf statistics (and with them the compaction liveness
    counts, which are the same reduction) psum to the single-host values
    bit-identically."""
    rng = np.random.default_rng(seed)
    _, g, h, w = _exact_case(rng, n, 1, T, 8, goss)
    assign = jnp.asarray(rng.integers(0, leaves, (T, n)), jnp.int32)
    full = hist_mod.round_leaf_stats(g, h, w, assign, leaves)
    bounds = _uneven_bounds(rng, n, shards)
    acc = jnp.zeros_like(full)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        acc = acc + hist_mod.round_leaf_stats(
            g[lo:hi], h[lo:hi], w[:, lo:hi], assign[:, lo:hi], leaves
        )
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(full))


def _check_sharded_root_delta(n, d, T, shards, seed):
    """The shared-root delta path (shared − masked-out delta, DESIGN.md §9)
    decomposes over row shards bit-identically: each shard's
    ``shared_s − delta_s`` covers exactly its local masked-out rows (the
    static budget bounds any shard's count), so the psum equals the
    single-host delta-path histogram."""
    rng = np.random.default_rng(seed)
    B = 8
    binned, g, h, w = _exact_case(rng, n, d, T, B, goss=False)
    zeros = jnp.zeros((T, n), jnp.int32)
    # budget = n covers every shard's masked-out rows (surplus slots are
    # weight-0 inert), mirroring boosting's n-capped delta budget
    full = hist_mod.compute_round_histogram(
        binned, g, h, w, zeros, 1, B, root_delta_rows=n
    )
    bounds = _uneven_bounds(rng, n, shards)
    acc = jnp.zeros_like(full)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        acc = acc + hist_mod.compute_round_histogram(
            binned[lo:hi], g[lo:hi], h[lo:hi], w[:, lo:hi],
            zeros[:, lo:hi], 1, B, root_delta_rows=n,
        )
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(full))


def _check_pack_roundtrip(n, T, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, (T, n)).astype(np.int32)
    packed = aggregator.pack_bits(jnp.asarray(x))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (T, -(-n // 8))
    np.testing.assert_array_equal(
        np.asarray(aggregator.unpack_bits(packed, n)), x
    )


def _check_pack_psum_is_or(n, parties, seed):
    """Each row's go-right bit has exactly one owning party, so the uint8
    byte-sum across parties equals the bitwise OR (no carries) — the
    property that lets the routing psum run on packed bitmaps."""
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, parties, n)
    bits = rng.integers(0, 2, n).astype(np.int32)
    per_party = [
        jnp.asarray(np.where(owner == p, bits, 0)[None, :])
        for p in range(parties)
    ]
    packed_sum = sum(aggregator.pack_bits(x) for x in per_party)
    np.testing.assert_array_equal(
        np.asarray(packed_sum),
        np.asarray(aggregator.pack_bits(jnp.asarray(bits[None, :]))),
    )
    np.testing.assert_array_equal(
        np.asarray(aggregator.unpack_bits(packed_sum, n))[0], bits
    )


def _check_wire_arithmetic(n, shards, depth):
    """The wire model's id_partition term: ``shards`` per-shard bitmaps of
    ``ceil(n_shard/8)`` bytes each per level, with rows padded to the shard
    granularity — brute-force cross-check of the ceil arithmetic."""
    phases = protocol.wire_party_tree_cost(
        n, 2, 8, depth, "histogram", data_shards=shards
    )
    n_shard = -(-n // shards)
    per_level = shards * ((n_shard + 7) // 8)
    assert phases["id_partition"] == depth * per_level
    # the padded total never undercounts the unsharded bitmap, and the
    # byte overhead of sharding is < 1 byte per shard per level
    unsharded = protocol.wire_party_tree_cost(n, 2, 8, depth, "histogram")
    assert phases["id_partition"] >= unsharded["id_partition"]
    assert phases["id_partition"] - unsharded["id_partition"] <= depth * shards


# ---------------------------------------------------------------------------
# deterministic sweeps — always run (tier-1 container has no hypothesis)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shards,goss,seed", SWEEP)
def test_sharded_round_histogram_bit_identical(shards, goss, seed):
    _check_sharded_histogram(n=357, d=5, T=3, nodes=4, shards=shards,
                             goss=goss, seed=seed)


@pytest.mark.parametrize("shards,goss,seed", SWEEP)
def test_sharded_leaf_stats_bit_identical(shards, goss, seed):
    _check_sharded_leaf_stats(n=301, T=3, leaves=8, shards=shards,
                              goss=goss, seed=seed)


@pytest.mark.parametrize("shards,seed", [(1, 0), (2, 1), (4, 2)])
def test_sharded_shared_root_delta_bit_identical(shards, seed):
    _check_sharded_root_delta(n=203, d=3, T=4, shards=shards, seed=seed)


@pytest.mark.parametrize("n", [1, 7, 8, 9, 64, 101])
def test_pack_bits_roundtrip(n):
    _check_pack_roundtrip(n=n, T=4, seed=n)


@pytest.mark.parametrize("parties,seed", [(2, 0), (4, 1)])
def test_pack_bits_psum_is_carry_free(parties, seed):
    _check_pack_psum_is_or(n=131, parties=parties, seed=seed)


@pytest.mark.parametrize("n,shards", [(1, 1), (701, 2), (1536, 4), (999, 8)])
def test_wire_id_partition_shard_arithmetic(n, shards):
    _check_wire_arithmetic(n=n, shards=shards, depth=3)


# ---------------------------------------------------------------------------
# hypothesis properties — same invariants over the drawn space
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(32, 400), d=st.integers(1, 6),
           T=st.sampled_from([1, 3]), nodes=st.sampled_from([1, 2, 4]),
           shards=st.sampled_from([1, 2, 4]), goss=st.booleans(),
           seed=st.integers(0, 2**16))
    def test_prop_sharded_histogram(n, d, T, nodes, shards, goss, seed):
        _check_sharded_histogram(n, d, T, nodes, shards, goss, seed)

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(32, 400), T=st.sampled_from([1, 3]),
           leaves=st.sampled_from([2, 4, 8]),
           shards=st.sampled_from([1, 2, 4]), goss=st.booleans(),
           seed=st.integers(0, 2**16))
    def test_prop_sharded_leaf_stats(n, T, leaves, shards, goss, seed):
        _check_sharded_leaf_stats(n, T, leaves, shards, goss, seed)

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(32, 300), d=st.integers(1, 4),
           T=st.sampled_from([2, 4]), shards=st.sampled_from([1, 2, 4]),
           seed=st.integers(0, 2**16))
    def test_prop_sharded_root_delta(n, d, T, shards, seed):
        _check_sharded_root_delta(n, d, T, shards, seed)

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(1, 200), T=st.sampled_from([1, 4]),
           seed=st.integers(0, 2**16))
    def test_prop_pack_roundtrip(n, T, seed):
        _check_pack_roundtrip(n, T, seed)

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(8, 200), parties=st.sampled_from([2, 4]),
           seed=st.integers(0, 2**16))
    def test_prop_pack_psum_is_or(n, parties, seed):
        _check_pack_psum_is_or(n, parties, seed)

    @needs_hypothesis
    @settings(**SETTINGS)
    @given(n=st.integers(1, 5000), shards=st.sampled_from([1, 2, 4, 8]),
           depth=st.integers(1, 5))
    def test_prop_wire_arithmetic(n, shards, depth):
        _check_wire_arithmetic(n, shards, depth)
