"""Scanned training engine vs legacy loop (DESIGN.md §4) + NaN-safe binning.

The load-bearing guarantee of this PR: the static-shape scanned engine —
the schedule factored into constant-width segments scanned inside one
compiled program — reproduces the legacy per-round loop's history metrics
to float tolerance and its trees structurally bit-for-bit, for static AND
dynamic schedules, so it can be the default engine everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, boosting
from repro.core.types import FedGBFConfig, TreeConfig


def _data(loss, seed=0, n=600, d=6):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    sig = x[:, 0] - 0.7 * x[:, 1] + rng.normal(0, 0.4, n).astype(np.float32)
    y = (sig > 0).astype(np.float32) if loss == "logistic" else sig
    xv = rng.normal(size=(211, d)).astype(np.float32)
    sv = xv[:, 0] - 0.7 * xv[:, 1]
    yv = (sv > 0).astype(np.float32) if loss == "logistic" else sv
    return map(jnp.asarray, (x, y, xv, yv))


def _dyn_cfg(loss, rounds=5):
    return FedGBFConfig(
        rounds=rounds, loss=loss, n_trees_max=5, n_trees_min=2,
        rho_id_min=0.3, rho_id_max=0.7,
        tree=TreeConfig(max_depth=3, num_bins=16),
    )


@pytest.mark.parametrize("loss", ["logistic", "squared"])
def test_scanned_engine_history_equals_loop(loss):
    """Acceptance bar: per-round train/valid metrics within 1e-5 of the
    legacy loop, same recorded schedule, structurally identical trees."""
    x, y, xv, yv = _data(loss)
    cfg = _dyn_cfg(loss)
    m_loop, h_loop = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), x_valid=xv, y_valid=yv, engine="loop")
    m_scan, h_scan = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), x_valid=xv, y_valid=yv, engine="scan")

    assert h_loop.engine == "loop" and h_scan.engine == "scan"
    assert h_scan.rounds == h_loop.rounds
    assert h_scan.n_trees == h_loop.n_trees
    np.testing.assert_allclose(h_scan.rho_id, h_loop.rho_id, rtol=1e-6)
    for a, b in zip(h_loop.train, h_scan.train):
        assert set(a) == set(b)
        for k in a:
            assert abs(a[k] - b[k]) < 1e-5, (k, a[k], b[k])
    for a, b in zip(h_loop.valid, h_scan.valid):
        for k in a:
            assert abs(a[k] - b[k]) < 1e-5, (k, a[k], b[k])

    # the dynamic schedule's ragged forests come out structurally identical
    assert m_scan.rounds == m_loop.rounds
    for f_loop, f_scan in zip(m_loop.forests, m_scan.forests):
        np.testing.assert_array_equal(
            np.asarray(f_loop.feature), np.asarray(f_scan.feature))
        np.testing.assert_array_equal(
            np.asarray(f_loop.threshold), np.asarray(f_scan.threshold))
        np.testing.assert_allclose(
            np.asarray(f_loop.leaf_weight), np.asarray(f_scan.leaf_weight),
            rtol=1e-5, atol=1e-6)


def test_goss_sampling_scan_equals_loop():
    """The GOSS rho-mask (DESIGN.md §5) rides the scan engine unchanged:
    per-slot keys stay prefix-stable, so loop and scan draw identical GOSS
    masks from the round's gradients — trees come out bit-identical and the
    history metrics agree like the uniform path's."""
    import dataclasses

    x, y, xv, yv = _data("logistic")
    cfg = dataclasses.replace(_dyn_cfg("logistic"), sampling="goss",
                              goss_top_share=0.5)
    m_loop, h_loop = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), x_valid=xv, y_valid=yv, engine="loop")
    m_scan, h_scan = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), x_valid=xv, y_valid=yv, engine="scan")
    for f_loop, f_scan in zip(m_loop.forests, m_scan.forests):
        np.testing.assert_array_equal(
            np.asarray(f_loop.feature), np.asarray(f_scan.feature))
        np.testing.assert_array_equal(
            np.asarray(f_loop.threshold), np.asarray(f_scan.threshold))
    for a, b in zip(h_loop.train, h_scan.train):
        for k in a:
            assert abs(a[k] - b[k]) < 1e-5, (k, a[k], b[k])


def test_goss_changes_masks_but_trains():
    """GOSS actually alters the sampling (different trees than uniform) and
    still learns the signal."""
    import dataclasses

    x, y, _, _ = _data("logistic", seed=9)
    cfg_u = _dyn_cfg("logistic", rounds=3)
    cfg_g = dataclasses.replace(cfg_u, sampling="goss")
    m_u, h_u = boosting.train_fedgbf(x, y, cfg_u, jax.random.PRNGKey(0))
    m_g, h_g = boosting.train_fedgbf(x, y, cfg_g, jax.random.PRNGKey(0))
    assert any(
        not np.array_equal(np.asarray(fu.feature), np.asarray(fg.feature))
        or not np.array_equal(np.asarray(fu.threshold), np.asarray(fg.threshold))
        for fu, fg in zip(m_u.forests, m_g.forests)
    )
    assert h_g.train[-1]["auc"] > 0.8


@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_history_records_every_round_with_eval_gating(engine):
    """Satellite guarantee: with eval_every > 1 the schedule and timing are
    still recorded for EVERY round; only the metric evals are gated."""
    x, y, xv, yv = _data("logistic")
    cfg = _dyn_cfg("logistic", rounds=5)
    _, hist = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(1), x_valid=xv, y_valid=yv,
        eval_every=2, engine=engine)
    assert len(hist.n_trees) == cfg.rounds
    assert len(hist.rho_id) == cfg.rounds
    assert len(hist.wall_time_s) == cfg.rounds
    assert hist.n_trees == [5, 5, 4, 3, 2]
    assert hist.rounds == [2, 4, 5]  # evals: every 2nd round + final
    assert len(hist.train) == 3 and len(hist.valid) == 3
    assert hist.total_wall_time_s > 0.0


def test_scanned_engine_eval_gating_matches_loop_values():
    """The gated (in-graph, lax.cond) evals equal the loop's host evals."""
    x, y, _, _ = _data("logistic", seed=3)
    cfg = _dyn_cfg("logistic", rounds=4)
    _, h_loop = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(2), eval_every=3, engine="loop")
    _, h_scan = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(2), eval_every=3, engine="scan")
    assert h_scan.rounds == h_loop.rounds == [3, 4]
    for a, b in zip(h_loop.train, h_scan.train):
        for k in a:
            assert abs(a[k] - b[k]) < 1e-5


def test_scanned_is_default_engine():
    x, y, _, _ = _data("logistic", seed=5)
    cfg = _dyn_cfg("logistic", rounds=2)
    _, hist = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    assert hist.engine == "scan"
    with pytest.raises(ValueError, match="unknown engine"):
        boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0), engine="bogus")


def test_static_schedule_single_forest_shape():
    """SecureBoost degeneration (1 tree/round) through the scanned engine."""
    x, y, _, _ = _data("logistic", seed=7)
    cfg = boosting.secureboost_config(rounds=3, tree=TreeConfig(max_depth=2,
                                                                num_bins=8))
    m_loop, h_loop = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(4),
                                           engine="loop")
    m_scan, h_scan = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(4),
                                           engine="scan")
    for f1, f2 in zip(m_loop.forests, m_scan.forests):
        np.testing.assert_array_equal(np.asarray(f1.feature),
                                      np.asarray(f2.feature))
    for a, b in zip(h_loop.train, h_scan.train):
        for k in a:
            assert abs(a[k] - b[k]) < 1e-5


# ---------------------------------------------------------------------------
# NaN-safe binning (missing values)
# ---------------------------------------------------------------------------
def test_bin_edges_nan_safe():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(500, 4)).astype(np.float32)
    x_miss = x.copy()
    x_miss[rng.random((500, 4)) < 0.3] = np.nan  # 30% missing
    edges = binning.quantile_bin_edges(jnp.asarray(x_miss), 16)
    assert np.all(np.isfinite(np.asarray(edges))), "NaNs leaked into edges"
    # edges fit on the observed values only: close to the edges nanquantile
    # of the dense column would give on the same observed subset
    col = x_miss[:, 0]
    obs = col[~np.isnan(col)]
    qs = np.linspace(0, 1, 17)[1:-1]
    np.testing.assert_allclose(
        np.asarray(edges)[0], np.quantile(obs, qs), rtol=1e-4, atol=1e-4)


def test_bin_data_routes_nan_deterministically():
    x = jnp.asarray(np.array([[0.0], [np.nan], [5.0], [np.nan]], np.float32))
    edges = jnp.asarray(np.array([[1.0, 2.0, 3.0]], np.float32))
    b = np.asarray(binning.bin_data(x, edges))
    assert b[1, 0] == binning.NAN_BIN and b[3, 0] == binning.NAN_BIN
    assert b[0, 0] == 0 and b[2, 0] == 3


def test_all_nan_column_degrades_to_unsplittable():
    rng = np.random.default_rng(12)
    x = rng.normal(size=(300, 3)).astype(np.float32)
    x[:, 1] = np.nan  # a completely missing feature
    binned, edges = binning.fit_bin(jnp.asarray(x), 8)
    assert np.all(np.isfinite(np.asarray(edges)))
    assert np.all(np.asarray(binned)[:, 1] == binning.NAN_BIN)


@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_training_with_missing_values(engine):
    """End-to-end: a credit-scoring-shaped table with missing cells trains
    to finite metrics and predicts finite margins on missing-valued input."""
    rng = np.random.default_rng(13)
    n, d = 500, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.float32)
    x[rng.random((n, d)) < 0.15] = np.nan
    cfg = FedGBFConfig(rounds=3, n_trees_max=3, n_trees_min=2,
                       rho_id_min=0.5, rho_id_max=0.8,
                       tree=TreeConfig(max_depth=3, num_bins=16))
    model, hist = boosting.train_fedgbf(
        jnp.asarray(x), jnp.asarray(y), cfg, jax.random.PRNGKey(5),
        engine=engine)
    assert all(np.isfinite(v) for rep in hist.train for v in rep.values())
    assert hist.train[-1]["loss"] < hist.train[0]["loss"] + 1e-6
    x_test = rng.normal(size=(97, d)).astype(np.float32)
    x_test[rng.random((97, d)) < 0.15] = np.nan
    margin = boosting.predict(model, jnp.asarray(x_test))
    assert np.all(np.isfinite(np.asarray(margin)))
