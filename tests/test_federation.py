"""Federated-vs-centralized losslessness (the SecureBoost/FedGBF guarantee).

The shard_map checks need >1 device, so they run in a subprocess with
XLA_FLAGS forcing 8 host devices — the main pytest process keeps its
single-device view (required by the smoke tests)."""

import os
import subprocess
import sys

import numpy as np

from repro.core.types import FedGBFConfig
from repro.federation import protocol

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_federated_lossless_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.federation.selftest"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL FEDERATION SELF-TESTS PASSED" in out.stdout


def test_protocol_costs_paper_scale():
    """Sanity-check ledger magnitudes on the Give-Me-Some-Credit shape:
    SecureBoost's dominant message is the encrypted gradient broadcast +
    histograms; FedGBF's subsampling cuts the gradient volume."""
    spec = protocol.ProtocolSpec(
        n_samples=105_000, party_dims=(5, 5), num_bins=32, max_depth=3
    )
    sb = protocol.run_cost(
        spec,
        FedGBFConfig(rounds=20, n_trees_max=1, n_trees_min=1,
                     rho_id_min=1.0, rho_id_max=1.0),
    )
    fg = protocol.run_cost(
        spec,
        FedGBFConfig(rounds=20, n_trees_max=5, n_trees_min=2,
                     rho_id_min=0.1, rho_id_max=0.3),
    )
    assert sb.total > 0 and fg.total > 0
    # gradient broadcast: SecureBoost ships all n ids each round; FedGBF at
    # most rho_id * n * trees (clipped at n)
    assert fg.grad_broadcast <= sb.grad_broadcast
    # per-tree histogram volume is identical per level; FedGBF builds more
    # trees but the paper's point is it needs FEWER ROUNDS for equal quality;
    # at equal rounds its histogram volume is higher:
    assert fg.histograms >= sb.histograms


def test_even_partition_and_padding():
    from repro.data import tabular

    x = np.zeros((10, 23), np.float32)
    xp, dp = tabular.pad_features(x, 4)
    assert dp == 24 and xp.shape == (10, 24)
    part = tabular.even_partition(24, 4)
    assert part.dims() == (6, 6, 6, 6)
    assert part.owner_of(0) == 0 and part.owner_of(23) == 3
    np.testing.assert_array_equal(xp[:, 23], 0)


def test_load_csv_real_tabular(tmp_path):
    """The real-data loader (comm_bench --dataset): header + numeric rows,
    named or positional label column, NaN-tolerant cells, shuffled
    train/test split in the synthetic Dataset shape."""
    from repro.data import tabular

    rng = np.random.default_rng(0)
    n = 40
    path = tmp_path / "toy.csv"
    with open(path, "w") as f:
        f.write("f0,f1,f2,label\n")
        for i in range(n):
            f0 = f"{rng.normal():.4f}"
            f1 = "" if i == 3 else f"{rng.normal():.4f}"  # missing cell -> NaN
            f.write(f"{f0},{f1},{rng.normal():.4f},{i % 2}\n")
    ds = tabular.load_csv(str(path), label_col="label", seed=1)
    assert ds.x_train.shape[1] == 3 and ds.name == "csv:toy.csv"
    assert ds.x_train.shape[0] + ds.x_test.shape[0] == n
    assert ds.x_train.shape[0] == int(0.7 * n)
    assert set(np.unique(np.concatenate([ds.y_train, ds.y_test]))) == {0.0, 1.0}
    assert np.isnan(np.concatenate([ds.x_train, ds.x_test])).sum() == 1
    # positional label (default: last column) selects the same column
    ds2 = tabular.load_csv(str(path), seed=1)
    np.testing.assert_array_equal(ds.x_train, ds2.x_train)
    np.testing.assert_array_equal(ds.y_train, ds2.y_train)
    # the padded/binned training path digests the loader's output
    import jax

    from repro.core import boosting
    from repro.core.types import FedGBFConfig, TreeConfig

    cfg = FedGBFConfig(rounds=2, n_trees_max=2, n_trees_min=2,
                       tree=TreeConfig(max_depth=2, num_bins=4))
    model, _ = boosting.train_fedgbf(
        np.asarray(ds.x_train), np.asarray(ds.y_train), cfg,
        jax.random.PRNGKey(0),
    )
    assert model.total_trees == 4
