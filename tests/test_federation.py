"""Federated-vs-centralized losslessness (the SecureBoost/FedGBF guarantee).

The shard_map checks need >1 device, so they run in a subprocess with
XLA_FLAGS forcing 8 host devices — the main pytest process keeps its
single-device view (required by the smoke tests)."""

import os
import subprocess
import sys

import numpy as np

from repro.core.types import FedGBFConfig
from repro.federation import protocol

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_federated_lossless_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.federation.selftest"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL FEDERATION SELF-TESTS PASSED" in out.stdout


def test_protocol_costs_paper_scale():
    """Sanity-check ledger magnitudes on the Give-Me-Some-Credit shape:
    SecureBoost's dominant message is the encrypted gradient broadcast +
    histograms; FedGBF's subsampling cuts the gradient volume."""
    spec = protocol.ProtocolSpec(
        n_samples=105_000, party_dims=(5, 5), num_bins=32, max_depth=3
    )
    sb = protocol.run_cost(
        spec,
        FedGBFConfig(rounds=20, n_trees_max=1, n_trees_min=1,
                     rho_id_min=1.0, rho_id_max=1.0),
    )
    fg = protocol.run_cost(
        spec,
        FedGBFConfig(rounds=20, n_trees_max=5, n_trees_min=2,
                     rho_id_min=0.1, rho_id_max=0.3),
    )
    assert sb.total > 0 and fg.total > 0
    # gradient broadcast: SecureBoost ships all n ids each round; FedGBF at
    # most rho_id * n * trees (clipped at n)
    assert fg.grad_broadcast <= sb.grad_broadcast
    # per-tree histogram volume is identical per level; FedGBF builds more
    # trees but the paper's point is it needs FEWER ROUNDS for equal quality;
    # at equal rounds its histogram volume is higher:
    assert fg.histograms >= sb.histograms


def test_even_partition_and_padding():
    from repro.data import tabular

    x = np.zeros((10, 23), np.float32)
    xp, dp = tabular.pad_features(x, 4)
    assert dp == 24 and xp.shape == (10, 24)
    part = tabular.even_partition(24, 4)
    assert part.dims() == (6, 6, 6, 6)
    assert part.owner_of(0) == 0 and part.owner_of(23) == 3
    np.testing.assert_array_equal(xp[:, 23], 0)
