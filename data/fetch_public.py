"""Fetch a public credit dataset into the CSV shape the benchmarks load.

The paper evaluates on two Kaggle datasets (Give Me Some Credit, Default of
Credit Card Clients; PAPER.md §4.1) that need authenticated downloads, so CI
cannot fetch them.  This script grabs the closest openly downloadable
stand-in — the UCI Statlog German Credit data (1000 rows, 24 numeric
features, binary default label) — and writes it as a plain labelled CSV
that ``repro.data.tabular.load_csv`` (and therefore
``benchmarks/comm_bench.py --dataset``) consumes directly:

    python data/fetch_public.py --out data/german_credit.csv
    PYTHONPATH=src python -m benchmarks.comm_bench \
        --dataset data/german_credit.csv

The committed ``data/credit_sample.csv`` is the OFFLINE stand-in: a small
deterministic sample drawn from the same credit-like generator the
synthetic benchmarks use (``repro.data.synthetic``), committed so the
``--dataset`` CSV path has a hermetic CI baseline without any network.
Re-generate it with ``--sample`` (bit-reproducible: fixed seed).
"""

from __future__ import annotations

import argparse
import os
import sys
import urllib.request

UCI_URL = (
    "https://archive.ics.uci.edu/ml/machine-learning-databases/"
    "statlog/german/german.data-numeric"
)


def fetch_german_credit(out: str) -> None:
    """Download the UCI numeric German Credit table -> labelled CSV.

    The source is whitespace-separated, 24 integer features + a {1, 2}
    label; the CSV gets a header row and a {0, 1} label (1 = bad credit)
    in the LAST column, the ``load_csv`` default.
    """
    raw = urllib.request.urlopen(UCI_URL, timeout=60).read().decode()
    rows = [line.split() for line in raw.strip().splitlines()]
    d = len(rows[0]) - 1
    with open(out, "w") as f:
        f.write(",".join([f"f{i}" for i in range(d)] + ["label"]) + "\n")
        for r in rows:
            label = int(r[-1]) - 1  # {1,2} -> {0,1}
            f.write(",".join(r[:-1] + [str(label)]) + "\n")
    print(f"wrote {len(rows)} rows x {d} features -> {out}")


def write_sample(out: str, n: int = 600, seed: int = 7) -> None:
    """Deterministic committed sample from the synthetic credit generator."""
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
    )
    from repro.data import synthetic

    x, y = synthetic._credit_like(
        __import__("numpy").random.default_rng(seed), n, 10,
        pos_rate=0.15, interaction_pairs=3,
    )
    with open(out, "w") as f:
        f.write(",".join([f"f{i}" for i in range(x.shape[1])] + ["label"])
                + "\n")
        for row, label in zip(x, y):
            f.write(",".join(f"{v:.6g}" for v in row)
                    + f",{int(label)}\n")
    print(f"wrote {n} rows x {x.shape[1]} features -> {out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="data/german_credit.csv")
    ap.add_argument("--sample", action="store_true",
                    help="regenerate the committed offline sample CSV "
                         "instead of downloading")
    args = ap.parse_args()
    if args.sample:
        write_sample(args.out)
    else:
        fetch_german_credit(args.out)


if __name__ == "__main__":
    main()
